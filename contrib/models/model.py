"""Unified parametric model covering all ten assigned architectures.

Families compose from the block library:
  dense / vlm:   [RMSNorm -> GQA attn -> RMSNorm -> SwiGLU] × L
  moe:           [RMSNorm -> GQA attn -> RMSNorm -> MoE(+dense residual)] × L
  hybrid:        [RMSNorm -> Mamba2] × L, with one *shared* attention+MLP
                 block applied every ``attn_every`` layers (Zamba)
  ssm (rwkv):    [RWKV6 time-mix + channel-mix] × L
  audio:         encoder [bidir attn] × enc_L  +  decoder [causal attn +
                 cross-attn] × L, stub frame-embedding frontend

Per-layer parameters are stacked on a leading L axis and scanned, so
AOT lowering stays one-layer-sized. Embeddings are tied with the LM
head. All public entry points are pure functions over (params, batch).

``RunConfig`` carries execution knobs that are perf-relevant but not
architectural: remat policy, scan on/off, activation-sharding hints.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import attention as attn_lib
from . import moe as moe_lib
from . import rwkv as rwkv_lib
from . import ssm as ssm_lib
from .layers import DTYPES, dense_init, mrope_positions, rms_norm, swiglu

__all__ = ["RunConfig", "param_specs", "init_params", "loss_fn", "prefill",
           "decode_state_specs", "init_decode_state", "decode_step"]


@dataclasses.dataclass(frozen=True)
class RunConfig:
    remat: str = "block"  # none | block | dots
    scan_layers: bool = True
    vis_prefix: int = 256  # vlm stub prefix length
    seq_shard: bool = False  # beyond-paper: shard saved activations on seq
    rwkv_chunked: bool = True  # chunked-parallel time-mix for train/prefill
    attn_chunk: Optional[int] = None  # online-softmax KV-chunk size
    moe_expert_chunk: int = 0  # stream expert FFN in E-chunks (0 = off)

    def remat_policy(self):
        if self.remat == "none":
            return None
        if self.remat == "dots":
            return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint_policies.nothing_saveable


# ---------------------------------------------------------------------------
# parameter specs / init
# ---------------------------------------------------------------------------


def _mlp_spec(cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    return {"w1": ((d, f), dtype), "w3": ((d, f), dtype), "w2": ((f, d), dtype)}


def _block_spec(cfg: ArchConfig, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    if cfg.rwkv:
        return rwkv_lib.rwkv_params_spec(cfg, dtype)
    if cfg.family == "hybrid":
        return {"ln": ((d,), dtype), "ssm": ssm_lib.ssm_params_spec(cfg, dtype)}
    blk = {
        "ln1": ((d,), dtype),
        "attn": attn_lib.attention_params_spec(cfg, dtype),
        "ln2": ((d,), dtype),
    }
    if cfg.is_moe:
        blk["moe"] = moe_lib.moe_params_spec(cfg, dtype)
    else:
        blk["mlp"] = _mlp_spec(cfg, dtype)
    return blk


def _dec_block_spec(cfg, dtype):
    d = cfg.d_model
    return {
        "ln1": ((d,), dtype),
        "attn": attn_lib.attention_params_spec(cfg, dtype),
        "lnx": ((d,), dtype),
        "xattn": attn_lib.attention_params_spec(cfg, dtype),
        "ln2": ((d,), dtype),
        "mlp": _mlp_spec(cfg, dtype),
    }


def _stack(spec, L: int):
    return jax.tree.map(
        lambda sd: ((L,) + sd[0], sd[1]),
        spec,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple),
    )


def param_specs(cfg: ArchConfig) -> Dict[str, Any]:
    """Full parameter tree as (shape, dtype) leaves."""
    dtype = DTYPES[cfg.dtype]
    d, v = cfg.d_model, cfg.vocab
    tree: Dict[str, Any] = {
        "emb": ((v, d), dtype),
        "final_norm": ((d,), dtype),
    }
    if cfg.is_encdec:
        tree["enc_blocks"] = _stack(_block_spec(cfg, dtype), cfg.enc_layers)
        tree["enc_norm"] = ((d,), dtype)
        tree["blocks"] = _stack(_dec_block_spec(cfg, dtype), cfg.n_layers)
    else:
        tree["blocks"] = _stack(_block_spec(cfg, dtype), cfg.n_layers)
    if cfg.family == "hybrid" and cfg.attn_every:
        tree["shared"] = {
            "ln1": ((d,), dtype),
            "attn": attn_lib.attention_params_spec(cfg, dtype),
            "ln2": ((d,), dtype),
            "mlp": _mlp_spec(cfg, dtype),
        }
    return tree


def _is_spec_leaf(x):
    return (
        isinstance(x, tuple)
        and len(x) == 2
        and isinstance(x[0], tuple)
    )


def specs_to_sds(tree):
    """(shape, dtype) leaves -> ShapeDtypeStruct leaves (dry-run)."""
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct(sd[0], sd[1]),
        tree,
        is_leaf=_is_spec_leaf,
    )


_ONES = ("ln", "ln1", "ln2", "lnx", "ln_x", "final_norm", "enc_norm",
         "gate_norm", "qnorm", "knorm", "u", "d_skip")
_ZEROS = ("conv_b", "bq", "bk", "bv")
_HALF = ("mu", "mu_c")


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    """Real initialization (smoke tests, examples). Dispatch by name."""
    specs = param_specs(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=_is_spec_leaf
    )
    keys = jax.random.split(key, len(flat))
    out = []
    for (path, (shape, dtype)), k in zip(flat, keys):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in _ONES:
            out.append(jnp.ones(shape, dtype))
        elif name in _ZEROS:
            out.append(jnp.zeros(shape, dtype))
        elif name in _HALF:
            out.append(jnp.full(shape, 0.5, dtype))
        elif name == "a_log":
            base = jnp.log(
                jnp.linspace(1.0, 16.0, shape[-1], dtype=jnp.float32)
            )
            out.append(jnp.broadcast_to(base, shape).astype(dtype))
        elif name == "dt_bias":
            out.append(jnp.full(shape, -2.0, dtype))
        elif name == "w0":
            out.append(jnp.full(shape, -1.0, dtype))
        else:
            out.append(dense_init(k, shape, dtype=dtype))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# forward blocks
# ---------------------------------------------------------------------------


def _attn_mlp_block(bp, x, cfg, pos=None, pos3=None, window=None,
                    chunk=None, moe_chunk=0):
    h = attn_lib.attention(
        bp["attn"], rms_norm(x, bp["ln1"]), cfg, causal=True,
        pos=pos, pos3=pos3, window=window, chunk=chunk,
    )
    x = x + h
    if "moe" in bp:
        x = x + moe_lib.moe_mlp(
            bp["moe"], rms_norm(x, bp["ln2"]), cfg,
            expert_chunk=moe_chunk,
        )
    else:
        m = bp["mlp"]
        x = x + swiglu(rms_norm(x, bp["ln2"]), m["w1"], m["w3"], m["w2"])
    return x


def _run_blocks(params, x, cfg: ArchConfig, run: RunConfig, pos=None, pos3=None):
    """Scan the decoder stack. Returns final hidden states."""
    shared = params.get("shared")

    def body(carry, bp_i):
        x, idx = carry
        bp, = bp_i
        if cfg.rwkv:
            if run.rwkv_chunked:
                x = rwkv_lib.rwkv_block_chunked(bp, x, cfg)
            else:
                x = rwkv_lib.rwkv_block(bp, x, cfg)
        elif cfg.family == "hybrid":
            x = x + ssm_lib.mamba2_forward(bp["ssm"], rms_norm(x, bp["ln"]), cfg)
            if shared is not None and cfg.attn_every:
                def with_attn(x):
                    return _attn_mlp_block(
                        shared, x, cfg, pos=pos, window=cfg.sliding_window,
                        chunk=run.attn_chunk,
                    )
                x = jax.lax.cond(
                    (idx + 1) % cfg.attn_every == 0, with_attn, lambda x: x, x
                )
        else:
            x = _attn_mlp_block(
                bp, x, cfg, pos=pos, pos3=pos3, window=cfg.sliding_window,
                chunk=run.attn_chunk, moe_chunk=run.moe_expert_chunk,
            )
        return (x, idx + 1), None

    if run.remat != "none":
        body = jax.checkpoint(body, policy=run.remat_policy(), prevent_cse=False)
    if run.scan_layers:
        (x, _), _ = jax.lax.scan(body, (x, jnp.int32(0)), (params["blocks"],))
    else:
        L = jax.tree.leaves(params["blocks"])[0].shape[0]
        carry = (x, jnp.int32(0))
        for i in range(L):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            carry, _ = body(carry, (bp,))
        x = carry[0]
    return x


def _encoder(params, src, cfg, run: RunConfig):
    def body(carry, bp_):
        x, = carry
        bp, = bp_
        h = attn_lib.attention(
            bp["attn"], rms_norm(x, bp["ln1"]), cfg, causal=False
        )
        x = x + h
        m = bp["mlp"]
        x = x + swiglu(rms_norm(x, bp["ln2"]), m["w1"], m["w3"], m["w2"])
        return (x,), None

    if run.remat != "none":
        body = jax.checkpoint(body, policy=run.remat_policy(), prevent_cse=False)
    if run.scan_layers:
        (x,), _ = jax.lax.scan(body, (src,), (params["enc_blocks"],))
    else:
        L = jax.tree.leaves(params["enc_blocks"])[0].shape[0]
        x = src
        for i in range(L):
            bp = jax.tree.map(lambda a: a[i], params["enc_blocks"])
            (x,), _ = body((x,), (bp,))
    return rms_norm(x, params["enc_norm"])


def _decoder_xattn(params, tgt, memory, cfg, run: RunConfig):
    def body(carry, bp_):
        x, = carry
        bp, = bp_
        x = x + attn_lib.attention(
            bp["attn"], rms_norm(x, bp["ln1"]), cfg, causal=True
        )
        # cross-attention: kv from encoder memory
        xq = rms_norm(x, bp["lnx"])
        b, t, _ = memory.shape
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        k = (memory @ bp["xattn"]["wk"]).reshape(b, t, kv, hd)
        v = (memory @ bp["xattn"]["wv"]).reshape(b, t, kv, hd)
        x = x + attn_lib.attention(
            bp["xattn"], xq, cfg, causal=False, kv_override=(k, v)
        )
        m = bp["mlp"]
        x = x + swiglu(rms_norm(x, bp["ln2"]), m["w1"], m["w3"], m["w2"])
        return (x,), None

    if run.remat != "none":
        body = jax.checkpoint(body, policy=run.remat_policy(), prevent_cse=False)
    if run.scan_layers:
        (x,), _ = jax.lax.scan(body, (tgt,), (params["blocks"],))
    else:
        L = jax.tree.leaves(params["blocks"])[0].shape[0]
        x = tgt
        for i in range(L):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            (x,), _ = body((x,), (bp,))
    return x


# ---------------------------------------------------------------------------
# training loss
# ---------------------------------------------------------------------------


def _xent(logits, labels, mask):
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, batch, cfg: ArchConfig, run: RunConfig = RunConfig()):
    """Next-token (or seq2seq) loss. Batch layout per family:

    dense/moe/ssm/hybrid: {"tokens": (B, S)}
    vlm:   {"tokens": (B, S - vis), "vis_embeds": (B, vis, D)}
    audio: {"src_embeds": (B, S, D), "tgt_tokens": (B, S)}
    """
    emb = params["emb"]
    if cfg.is_encdec:
        memory = _encoder(params, batch["src_embeds"], cfg, run)
        tgt = batch["tgt_tokens"]
        x = emb[tgt]
        x = _decoder_xattn(params, x, memory, cfg, run)
        x = rms_norm(x, params["final_norm"])
        logits = x @ emb.T
        labels = jnp.roll(tgt, -1, axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        return _xent(logits, labels, mask)

    tokens = batch["tokens"]
    x = emb[tokens]
    pos3 = None
    pos = None
    if cfg.family == "vlm":
        vis = batch["vis_embeds"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        b, s, _ = x.shape
        pos3 = mrope_positions(b, s)
    x = _run_blocks(params, x, cfg, run, pos=pos, pos3=pos3)
    x = rms_norm(x, params["final_norm"])
    if cfg.family == "vlm":
        x = x[:, batch["vis_embeds"].shape[1] :]
    logits = x @ emb.T
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    return _xent(logits, labels, mask)


# ---------------------------------------------------------------------------
# inference: prefill + single-token decode
# ---------------------------------------------------------------------------


def prefill(params, batch, cfg: ArchConfig, run: RunConfig = RunConfig()):
    """Process the prompt; return last-position logits (B, V).

    (KV-cache materialization for subsequent decode happens in
    ``init_decode_state`` + replay or via serving-side chunked prefill;
    this entry point is the compute-shape used for the prefill cells.)
    """
    emb = params["emb"]
    if cfg.is_encdec:
        memory = _encoder(params, batch["src_embeds"], cfg, run)
        x = emb[batch["tgt_tokens"]]
        x = _decoder_xattn(params, x, memory, cfg, run)
    else:
        tokens = batch["tokens"]
        x = emb[tokens]
        pos3 = None
        if cfg.family == "vlm":
            vis = batch["vis_embeds"].astype(x.dtype)
            x = jnp.concatenate([vis, x], axis=1)
            pos3 = mrope_positions(x.shape[0], x.shape[1])
        x = _run_blocks(params, x, cfg, run, pos3=pos3)
    x = rms_norm(x[:, -1:], params["final_norm"])
    return (x @ emb.T)[:, 0]


def decode_state_specs(cfg: ArchConfig, bsz: int, cache_len: int):
    """(shape, dtype) tree of the per-request decode state."""
    dtype = DTYPES[cfg.dtype]
    L = cfg.n_layers
    kvd = cfg.n_kv_heads * cfg.head_dim
    d = cfg.d_model
    if cfg.rwkv:
        h, hd = cfg.n_heads, cfg.head_dim
        return {
            "shift_a": ((L, bsz, d), dtype),
            "shift_c": ((L, bsz, d), dtype),
            "wkv": ((L, bsz, h, hd, hd), jnp.float32),
            "length": ((), jnp.int32),
        }
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * d
        h_m = d_in // 64
        conv_dim = d_in + 2 * cfg.ssm_state
        n_apps = cfg.n_layers // max(cfg.attn_every, 1)
        w = cfg.sliding_window or cache_len
        w = min(w, cache_len)
        return {
            "conv": ((L, bsz, cfg.ssm_conv - 1, conv_dim), dtype),
            "h": ((L, bsz, h_m, 64, cfg.ssm_state), jnp.float32),
            "k": ((n_apps, bsz, w, kvd), dtype),
            "v": ((n_apps, bsz, w, kvd), dtype),
            "length": ((), jnp.int32),
        }
    if cfg.is_encdec:
        return {
            "k": ((L, bsz, cache_len, kvd), dtype),
            "v": ((L, bsz, cache_len, kvd), dtype),
            "memory": ((bsz, cache_len, d), dtype),
            "length": ((), jnp.int32),
        }
    return {
        "k": ((L, bsz, cache_len, kvd), dtype),
        "v": ((L, bsz, cache_len, kvd), dtype),
        "length": ((), jnp.int32),
    }


def init_decode_state(cfg, bsz, cache_len):
    return jax.tree.map(
        lambda sd: jnp.zeros(sd[0], sd[1]),
        decode_state_specs(cfg, bsz, cache_len),
        is_leaf=_is_spec_leaf,
    )


def _scan_or_unroll(body, carry, xs, use_scan: bool):
    """lax.scan, or an unrolled python loop (the dry-run's depth-1/2
    cost-extrapolation variants need every layer present in the HLO —
    XLA cost_analysis counts loop bodies once)."""
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


def decode_step(params, state, token, cfg: ArchConfig,
                run: RunConfig = RunConfig()):
    """One decode step: token (B, 1) int32 -> (logits (B, V), new state).

    Layer-stacked caches are threaded through the layer scan as scanned
    inputs/outputs, so the compiled step stays one-layer-sized.
    """
    emb = params["emb"]
    x = emb[token]
    length = state["length"]

    if cfg.rwkv:
        def body(x, per_layer):
            bp, sa, sc, wkv = per_layer
            st = rwkv_lib.RWKVState(sa, sc, wkv)
            out, st2 = rwkv_lib.rwkv_decode(bp, x, cfg, st)
            return out, (st2.shift_a, st2.shift_c, st2.wkv)

        x, (sa, sc, wkv) = _scan_or_unroll(
            body, x, (params["blocks"], state["shift_a"], state["shift_c"],
                      state["wkv"]), run.scan_layers,
        )
        new_state = {"shift_a": sa, "shift_c": sc, "wkv": wkv,
                     "length": length + 1}
    elif cfg.family == "hybrid":
        shared = params["shared"]
        w = state["k"].shape[2]
        n_apps = state["k"].shape[0]

        def body(carry, per_layer):
            x, idx, caches = carry
            bp, conv, h = per_layer
            st = ssm_lib.SSMState(conv, h)
            out, st2 = ssm_lib.mamba2_decode(
                bp["ssm"], rms_norm(x, bp["ln"]), st, cfg
            )
            x = x + out

            def with_attn(args):
                x, caches = args
                ck, cv = caches
                app = idx // cfg.attn_every
                ckl = jax.lax.dynamic_index_in_dim(ck, app, 0, keepdims=False)
                cvl = jax.lax.dynamic_index_in_dim(cv, app, 0, keepdims=False)
                # ring position within the sliding window
                wpos = jnp.minimum(length, w - 1)
                h_at, nk, nv = attn_lib.decode_attention(
                    shared["attn"], rms_norm(x, shared["ln1"]), ckl, cvl,
                    wpos, cfg, window=None,
                )
                x = x + h_at
                m = shared["mlp"]
                x = x + swiglu(rms_norm(x, shared["ln2"]), m["w1"], m["w3"],
                               m["w2"])
                ck = jax.lax.dynamic_update_index_in_dim(ck, nk, app, 0)
                cv = jax.lax.dynamic_update_index_in_dim(cv, nv, app, 0)
                return x, (ck, cv)

            x, caches = jax.lax.cond(
                (idx + 1) % cfg.attn_every == 0, with_attn,
                lambda a: a, (x, caches),
            )
            return (x, idx + 1, caches), (st2.conv, st2.h)

        (x, _, (ck, cv)), (conv, h) = _scan_or_unroll(
            body,
            (x, jnp.int32(0), (state["k"], state["v"])),
            (params["blocks"], state["conv"], state["h"]),
            run.scan_layers,
        )
        new_state = {"conv": conv, "h": h, "k": ck, "v": cv,
                     "length": length + 1}
    elif cfg.is_encdec:
        memory = state["memory"]

        def body(x, per_layer):
            bp, ck, cv = per_layer
            h_at, nk, nv = attn_lib.decode_attention(
                bp["attn"], rms_norm(x, bp["ln1"]), ck, cv, length, cfg
            )
            x = x + h_at
            xq = rms_norm(x, bp["lnx"])
            b, t, _ = memory.shape
            kv, hd = cfg.n_kv_heads, cfg.head_dim
            k = (memory @ bp["xattn"]["wk"]).reshape(b, t, kv, hd)
            v = (memory @ bp["xattn"]["wv"]).reshape(b, t, kv, hd)
            x = x + attn_lib.attention(
                bp["xattn"], xq, cfg, causal=False, kv_override=(k, v)
            )
            m = bp["mlp"]
            x = x + swiglu(rms_norm(x, bp["ln2"]), m["w1"], m["w3"], m["w2"])
            return x, (nk, nv)

        x, (ck, cv) = _scan_or_unroll(
            body, x, (params["blocks"], state["k"], state["v"]),
            run.scan_layers,
        )
        new_state = dict(state, k=ck, v=cv, length=length + 1)
    else:
        def body(x, per_layer):
            bp, ck, cv = per_layer
            h_at, nk, nv = attn_lib.decode_attention(
                bp["attn"], rms_norm(x, bp["ln1"]), ck, cv, length, cfg,
                window=cfg.sliding_window,
            )
            x = x + h_at
            if "moe" in bp:
                x = x + moe_lib.moe_mlp(bp["moe"], rms_norm(x, bp["ln2"]), cfg)
            else:
                m = bp["mlp"]
                x = x + swiglu(rms_norm(x, bp["ln2"]), m["w1"], m["w3"],
                               m["w2"])
            return x, (nk, nv)

        x, (ck, cv) = _scan_or_unroll(
            body, x, (params["blocks"], state["k"], state["v"]),
            run.scan_layers,
        )
        new_state = dict(state, k=ck, v=cv, length=length + 1)

    x = rms_norm(x[:, -1:], params["final_norm"])
    logits = (x @ emb.T)[:, 0]
    return logits, new_state
