"""Butterfly co-routing diagnostics for MoE routers (DESIGN.md §4).

The router's token→expert top-k assignment is a bipartite graph; its
butterfly density measures how strongly token *pairs* co-occur on
expert *pairs*. A collapsed router (all tokens on the same top experts)
maximizes butterflies; a balanced random router minimizes them. We
demonstrate on the reduced moonshot config against (a) a trained-ish
random router and (b) an artificially collapsed one.

    PYTHONPATH=src python examples/moe_routing_analysis.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import BipartiteGraph, count_butterflies
from repro.models import init_params
from repro.models.moe import routing_assignment


def density(toks, experts, n_experts):
    toks = np.asarray(toks)
    experts = np.asarray(experts)
    n_tok = int(toks.max()) + 1
    g = BipartiteGraph(
        n_tok, n_experts, np.stack([toks, experts], axis=1)
    )
    b = int(count_butterflies(g, order="side", aggregation="sort").total)
    pairs = n_tok * (n_tok - 1) / 2
    return b, b / pairs


def main():
    cfg = get_config("moonshot-v1-16b-a3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    bp0 = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
    x = jax.random.normal(
        jax.random.PRNGKey(1), (4, 64, cfg.d_model), jnp.float32
    ).astype(jnp.bfloat16)

    toks, experts = routing_assignment(bp0["moe"], x, cfg)
    b, d = density(toks, experts, cfg.n_experts)
    print(f"random-init router : {b:8,} butterflies "
          f"(density {d:.3f} per token pair)")

    # collapsed router: everyone picks experts {0, 1}
    collapsed = jnp.stack(
        [jnp.zeros_like(experts[::2]), jnp.ones_like(experts[1::2])], axis=1
    ).reshape(-1)
    b2, d2 = density(toks, collapsed, cfg.n_experts)
    print(f"collapsed router   : {b2:8,} butterflies "
          f"(density {d2:.3f} per token pair)")
    print(f"collapse amplifies co-routing butterflies {b2 / max(b,1):.1f}x "
          f"-> usable as a load-balance alarm in the train loop "
          f"(TrainConfig.diag_every)")


if __name__ == "__main__":
    main()
